"""Union-engine benchmark: persistent device loop vs host-driven rounds.

The headline comparison is the one the ROADMAP's perf trajectory tracks:
``fused_rounds="device"`` (the whole multi-round Algorithm-1 loop inside one
jitted ``lax.while_loop`` — one device→host sync per ``sample(n)``) against
``fused_rounds="host"`` (the PR-4 host-driven round loop: one jitted round
per dispatch, ``np.asarray`` fetch + Python banking between rounds — O(rounds)
syncs) on the UQ1 2-join union, swept over round-batch sizes.  The host loop
degrades as the round batch shrinks (more rounds → more syncs) while the
device loop is flat, which is exactly the O(rounds)→O(1) sync story.

Secondary rows cover the numpy reference engine, the §8.3 predicate regime
(``uq2push``/``uq2rej``: UQ2 under pushdown masks vs fused rejection
predicates, device vs host at the smallest swept round batch), and the other
union shapes (5-join chain, tree, cyclic).  Structured results land in
``BENCH_union.json`` via ``--json`` (samples/s, rounds, psi, device count,
git sha).

Timing protocol: every engine is warmed with a full-size ``sample(n)`` first —
the device loop compiles one program per output-capacity class, so a small
warm-up call would leave the big capacity's compile inside the timed region —
then the best of ``--repeats`` timed calls is reported (single-core containers
are noisy).

    PYTHONPATH=src python -m benchmarks.union_engine --smoke --json BENCH_union.json
"""

from __future__ import annotations

import argparse
import sys
import time

from repro.core.framework import estimate_union, warmup
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1, uq2, uq3, uq4

from .common import emit, record, write_json

# round-batch sweep for the headline host-vs-device comparison
_RB_SWEEP = (256, 512, 1024, 4096)


def _measure(sampler, n: int, repeats: int, rb: int) -> dict:
    """Warm (compile + banks) then best-of-``repeats`` steady-state timing."""
    sampler.sample(n)                        # compiles the n-capacity program
    # iterations advance by the per-round slot total (sum of the balanced
    # per-piece batches, >= round_batch), so rounds = iterations / that
    eng = getattr(sampler, "_engine", None)
    bt = sum(getattr(eng, "piece_batches", None) or [rb])
    best = float("inf")
    its = draws = rounds = 0
    for _ in range(repeats):
        it0 = sampler.stats.iterations
        cd0 = sampler.stats.candidate_draws
        t0 = time.perf_counter()
        sampler.sample(n)
        dt = time.perf_counter() - t0
        if dt < best:
            best = dt
            its = sampler.stats.iterations - it0
            draws = sampler.stats.candidate_draws - cd0
            # adaptive budgets shrink per-round draws, so iterations no
            # longer advance by a fixed slot total — prefer the engine's
            # own round counter when it exposes one
            rounds = int(getattr(eng, "last_rounds", 0) or its // max(bt, 1))
    return {
        "n": n,
        "seconds": best,
        "samples_per_s": n / max(best, 1e-9),
        "rounds": rounds,
        "iterations": its,
        "psi": draws / n,
    }


def _measure_interleaved(tagged, n: int, repeats: int, rb: int) -> dict:
    """Best-of timing with the engines' repeats interleaved round-robin.

    Matched-config comparisons (static vs adaptive plan) need both engines
    to see the same machine load; sequential sweeps separated by minutes of
    other benchmarks let background-load drift masquerade as (or mask) a
    real speedup.  Warm both, then alternate single-call repeats."""
    samplers = dict(tagged)
    for s in samplers.values():
        s.sample(n)                          # compile + warm the banks
    out = {t: {"n": n, "seconds": float("inf")} for t in samplers}
    for _ in range(repeats):
        for t, s in samplers.items():
            eng = getattr(s, "_engine", None)
            it0 = s.stats.iterations
            cd0 = s.stats.candidate_draws
            t0 = time.perf_counter()
            s.sample(n)
            dt = time.perf_counter() - t0
            m = out[t]
            if dt < m["seconds"]:
                bt = sum(getattr(eng, "piece_batches", None) or [rb])
                its = s.stats.iterations - it0
                m.update(
                    seconds=dt, samples_per_s=n / max(dt, 1e-9),
                    iterations=its,
                    rounds=int(getattr(eng, "last_rounds", 0)
                               or its // max(bt, 1)),
                    psi=(s.stats.candidate_draws - cd0) / n)
    return out


def _engine(wl, cover, mode: str, rb: int, seed: int = 5,
            plan: str = "static") -> SetUnionSampler:
    return SetUnionSampler(wl.cat, wl.joins, cover, seed=seed,
                           backend="jax", round_batch=rb, fused_rounds=mode,
                           plan=plan)


def _bench_pair(tag: str, wl, cover, n: int, rb: int, repeats: int):
    """Host-driven vs device-resident loop at one matched configuration."""
    res = {}
    for mode in ("host", "device"):
        m = _measure(_engine(wl, cover, mode, rb), n, repeats, rb)
        res[mode] = m
        emit(f"union_engine_{tag}_{mode}_rb{rb}", m["seconds"] / n * 1e6,
             f"rate={m['samples_per_s']:,.0f}/s rounds={m['rounds']} "
             f"psi={m['psi']:.2f}")
        record(f"{tag}_{mode}_rb{rb}", engine=mode, round_batch=rb,
               workload=tag, **m)
    sp = res["device"]["samples_per_s"] / max(res["host"]["samples_per_s"],
                                              1e-9)
    emit(f"union_engine_{tag}_speedup_rb{rb}", 0.0,
         f"device/host={sp:.2f}x")
    return res, sp


def _bench_numpy(tag: str, wl, cover, n: int) -> None:
    host = SetUnionSampler(wl.cat, wl.joins, cover, seed=5)
    host.sample(512)
    t0 = time.perf_counter()
    host.sample(n)
    dt = time.perf_counter() - t0
    emit(f"union_engine_{tag}_numpy", dt / n * 1e6,
         f"rate={n/max(dt,1e-9):,.0f}/s")
    record(f"{tag}_numpy", engine="numpy", workload=tag, n=n, seconds=dt,
           samples_per_s=n / max(dt, 1e-9))


def run(args) -> int:
    n = args.samples
    wl2 = uq1(scale=args.scale, overlap=0.4, seed=0, n_joins=2)
    wr = warmup(wl2.cat, wl2.joins, method="exact")
    cover2 = estimate_union(wr.oracle).cover

    # headline: UQ1 2-join, host loop vs device loop across round batches.
    # The host loop pays one device→host sync per round, so it degrades as
    # the round batch shrinks; the device loop is flat — the matched-config
    # speedup at small batches is the O(rounds)→O(1) sync win.
    best_host = best_dev = 0.0
    matched = {}
    for rb in args.rb_sweep:
        res, sp = _bench_pair("uq1x2", wl2, cover2, n, rb, args.repeats)
        matched[rb] = sp
        best_host = max(best_host, res["host"]["samples_per_s"])
        best_dev = max(best_dev, res["device"]["samples_per_s"])
    speedup = max(matched.values())
    emit("union_engine_uq1x2_summary", 0.0,
         f"matched-config device/host speedup max={speedup:.2f}x "
         f"(best device {best_dev:,.0f}/s, best host loop "
         f"{best_host:,.0f}/s)")
    record("uq1x2_summary", workload="uq1x2",
           matched_speedup={str(rb): s for rb, s in matched.items()},
           max_matched_speedup=speedup,
           best_device_samples_per_s=best_dev,
           best_host_samples_per_s=best_host)

    # adaptive round planner vs the static device loop at matched configs:
    # EMA-budgeted candidate draws over the expanded, demand-matched round
    # shapes against the fixed per-round batch.  Each rb is measured as an
    # interleaved static/adaptive pair so machine-load drift across the
    # sweep cancels out of the ratio.  The rb=256 row is the
    # perf_gate-enforced tentpole target (>= 1.3x).
    adaptive_sp = {}
    for rb in args.rb_sweep:
        pair = _measure_interleaved(
            [("static", _engine(wl2, cover2, "device", rb)),
             ("adaptive", _engine(wl2, cover2, "device", rb,
                                  plan="adaptive"))],
            n, max(args.repeats, 4), rb)
        m, ms = pair["adaptive"], pair["static"]
        sp = m["samples_per_s"] / max(ms["samples_per_s"], 1e-9)
        adaptive_sp[rb] = sp
        emit(f"union_engine_uq1x2_adaptive_rb{rb}", m["seconds"] / n * 1e6,
             f"rate={m['samples_per_s']:,.0f}/s rounds={m['rounds']} "
             f"psi={m['psi']:.2f} vs-static={sp:.2f}x")
        record(f"uq1x2_adaptive_rb{rb}", engine="device", plan="adaptive",
               round_batch=rb, workload="uq1x2",
               static_samples_per_s=ms["samples_per_s"],
               static_psi=ms["psi"],
               adaptive_vs_static=sp, **m)
    gate_rb = 256 if 256 in adaptive_sp else min(adaptive_sp)
    adaptive_speedup = adaptive_sp[gate_rb]
    emit("union_engine_uq1x2_adaptive_summary", 0.0,
         f"adaptive/static @rb{gate_rb}={adaptive_speedup:.2f}x "
         + " ".join(f"rb{rb}={s:.2f}x" for rb, s in sorted(adaptive_sp.items())))
    record("uq1x2_adaptive_summary", workload="uq1x2", plan="adaptive",
           gate_round_batch=gate_rb,
           adaptive_speedup={str(rb): s for rb, s in adaptive_sp.items()},
           adaptive_vs_static=adaptive_speedup)

    _bench_numpy("uq1x2", wl2, cover2, min(n, 20_000))

    # §8.3 predicate regime: the same UQ2 base chain under pushdown
    # (build-time validity masks — the filter is paid once at build, so the
    # per-draw cost matches an unfiltered join) and rejection (fused
    # in-round acceptance masks) predicates.  These unions previously
    # forced the host Algorithm-1 loop; the sweep pins the device win at
    # the small round batch where per-round sync cost bites hardest.
    pred_rb = min(args.rb_sweep)
    pred_sp = {}
    for ptag, pmode in (("uq2push", "pushdown"), ("uq2rej", "rejection")):
        wlq = uq2(scale=args.scale, seed=0, pred_mode=pmode)
        wrq = warmup(wlq.cat, wlq.joins, method="exact")
        covq = estimate_union(wrq.oracle).cover
        _, sp = _bench_pair(ptag, wlq, covq, n, pred_rb, args.repeats)
        pred_sp[ptag] = sp
        if pmode == "pushdown":
            _bench_numpy(ptag, wlq, covq, min(n, 20_000))
    emit("union_engine_uq2pred_summary", 0.0,
         f"device/host @rb{pred_rb}: pushdown={pred_sp['uq2push']:.2f}x "
         f"rejection={pred_sp['uq2rej']:.2f}x")
    record("uq2pred_summary", workload="uq2pred", round_batch=pred_rb,
           pushdown_speedup=pred_sp["uq2push"],
           rejection_speedup=pred_sp["uq2rej"])

    if not args.smoke:
        # coverage rows: other union shapes, device loop at the default batch
        for tag, wl, nn in (
                ("uq1x5", uq1(scale=args.scale, overlap=0.4, seed=0,
                              n_joins=5), n),
                ("uq3tree", uq3(scale=args.scale, overlap=0.3, seed=0), n),
                ("uq4cyclic", uq4(scale=args.scale, seed=0), n // 5)):
            wrx = warmup(wl.cat, wl.joins, method="exact")
            cov = estimate_union(wrx.oracle).cover
            m = _measure(_engine(wl, cov, "device", 4096), nn, args.repeats,
                         4096)
            emit(f"union_engine_{tag}_device_rb4096", m["seconds"] / nn * 1e6,
                 f"rate={m['samples_per_s']:,.0f}/s rounds={m['rounds']} "
                 f"psi={m['psi']:.2f}")
            record(f"{tag}_device_rb4096", engine="device", round_batch=4096,
                   workload=tag, **m)

    # telemetry overhead: identical device engine, obs on vs forced off.
    # Per-piece counters ride in the jitted carry either way (parity), so
    # this isolates the host-side cost (timers + registry folds) — the
    # acceptance bar is within 3%.
    from repro import obs
    rb = max(args.rb_sweep)
    m_on = _measure(_engine(wl2, cover2, "device", rb, seed=6), n,
                    args.repeats, rb)
    obs.set_enabled(False)
    try:
        m_off = _measure(_engine(wl2, cover2, "device", rb, seed=6), n,
                         args.repeats, rb)
    finally:
        obs.set_enabled(None)
    overhead = (m_off["samples_per_s"] / max(m_on["samples_per_s"], 1e-9)
                - 1.0)
    emit("union_engine_obs_overhead", 0.0,
         f"obs_on={m_on['samples_per_s']:,.0f}/s "
         f"obs_off={m_off['samples_per_s']:,.0f}/s "
         f"overhead={overhead * 100:.1f}%")
    record("obs_overhead", workload="uq1x2", round_batch=rb,
           samples_per_s_obs_on=m_on["samples_per_s"],
           samples_per_s_obs_off=m_off["samples_per_s"],
           overhead_pct=overhead * 100)

    write_json(args.json, bench="union_engine", scale=args.scale)

    rc = 0
    if args.require_device_speedup:
        if speedup < args.require_device_speedup:
            print(f"FAIL: device/host speedup {speedup:.2f}x < required "
                  f"{args.require_device_speedup}x", flush=True)
            rc = 1
        else:
            print(f"PASS: device/host speedup {speedup:.2f}x >= "
                  f"{args.require_device_speedup}x", flush=True)
    if args.require_adaptive_speedup:
        if adaptive_speedup < args.require_adaptive_speedup:
            print(f"FAIL: adaptive/static speedup {adaptive_speedup:.2f}x "
                  f"@rb{gate_rb} < required {args.require_adaptive_speedup}x",
                  flush=True)
            rc = 1
        else:
            print(f"PASS: adaptive/static speedup {adaptive_speedup:.2f}x "
                  f"@rb{gate_rb} >= {args.require_adaptive_speedup}x",
                  flush=True)
    return rc


def _parse(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small n + headline comparison only (CI perf-smoke)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write structured results (BENCH_union.json)")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--samples", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--rb-sweep", type=int, nargs="+", default=None)
    ap.add_argument("--require-device-speedup", type=float, default=0.0,
                    help="exit non-zero when the best matched-config "
                         "device/host speedup is below this")
    ap.add_argument("--require-adaptive-speedup", type=float, default=0.0,
                    help="exit non-zero when the adaptive/static speedup at "
                         "rb=256 (or the smallest swept batch) is below this")
    args = ap.parse_args(argv)
    if args.samples is None:
        args.samples = 20_000 if args.smoke else 100_000
    if args.repeats is None:
        args.repeats = 2 if args.smoke else 3
    if args.rb_sweep is None:
        args.rb_sweep = [256, 1024] if args.smoke else list(_RB_SWEEP)
    return args


def main(small: bool = True) -> None:
    """benchmarks.run entry point."""
    rc = run(_parse(["--smoke"] if small else []))
    if rc:
        raise RuntimeError("union_engine gate failed")


if __name__ == "__main__":
    sys.exit(run(_parse()))
