"""Union-engine benchmark: fused device rounds across workload shapes.

Sweeps the backend-abstracted ``SetUnionSampler`` over union workloads
(chain-only UQ1, tree-shaped UQ3, cyclic UQ4) and round-batch sizes, reporting
samples/sec for the host engine vs the fused jitted engine plus the
device engine's accounting (candidate draws per emitted sample).  The
device path runs one jitted program per Algorithm-1 round — multinomial
cover selection, candidate generation for all joins, membership masks,
compaction — so its per-sample cost is flat in ``n``.
"""

from __future__ import annotations

import time

from repro.core.framework import estimate_union, warmup
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1, uq3, uq4

from .common import emit


def _bench_one(tag: str, wl, n: int, round_batch: int) -> None:
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)

    host = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=5)
    host.sample(512)
    t0 = time.perf_counter()
    host.sample(n)
    t_host = time.perf_counter() - t0

    dev = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=5,
                          backend="jax", round_batch=round_batch)
    dev.sample(512)                          # compile
    stats0 = dev.stats.candidate_draws
    t0 = time.perf_counter()
    dev.sample(n)
    t_dev = time.perf_counter() - t0
    psi = (dev.stats.candidate_draws - stats0) / n

    emit(f"union_engine_{tag}_host", t_host / n * 1e6,
         f"rate={n/max(t_host,1e-9):,.0f}/s")
    emit(f"union_engine_{tag}_jax_rb{round_batch}", t_dev / n * 1e6,
         f"rate={n/max(t_dev,1e-9):,.0f}/s "
         f"speedup={t_host/max(t_dev,1e-9):.2f}x psi={psi:.2f}")


def main(small: bool = True) -> None:
    scale = 0.1 if small else 0.5
    n = 50_000 if small else 400_000
    wl2 = uq1(scale=scale, overlap=0.4, seed=0, n_joins=2)
    _bench_one("uq1x2", wl2, n, 16384)
    wl5 = uq1(scale=scale, overlap=0.4, seed=0, n_joins=5)
    _bench_one("uq1x5", wl5, n, 16384)
    wlt = uq3(scale=scale, overlap=0.3, seed=0)
    _bench_one("uq3tree", wlt, n, 16384)
    # cyclic union (§8.2 skeleton+residual rejection inside the fused round);
    # smaller n — the host engine pays the residual rejections per walk
    wlc = uq4(scale=scale, seed=0)
    _bench_one("uq4cyclic", wlc, n // 5, 16384)
    # round-batch sensitivity on the 2-join union
    for rb in (4096, 32768) if small else (8192, 65536):
        _bench_one(f"uq1x2_rb{rb}", wl2, n, rb)


if __name__ == "__main__":
    main(small=False)
