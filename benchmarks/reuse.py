"""Fig 6a/6b: ONLINE-UNION with sample reuse vs without.

Also dumps the φ-refinement trajectory (``OnlineUnionSampler.trace``): one
``# phi-trace`` JSON line per workload with the refresh/backtrack history,
plus a structured record when ``--json`` is given.
"""

from __future__ import annotations

import json
import time

from repro.core.framework import estimate_union, warmup
from repro.core.online import OnlineUnionSampler
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1, uq2, uq3

from .common import emit, record


def _dump_trace(tag, ou):
    """Print + record the φ-trajectory the sampler used to throw away."""
    refreshes = ou.trace.events("refresh")
    summary = {
        "workload": tag,
        "refreshes": ou.refresh_count,
        "last_refresh_at": ou.last_refresh_at,
        "backtrack_removed": ou.backtrack_count,
        "union_size": [e["union_size"] for e in refreshes],
        "hist_gap": refreshes[-1]["hist_gap"] if refreshes else {},
        "confident": refreshes[-1]["confident"] if refreshes else False,
    }
    print(f"# phi-trace {json.dumps(summary, sort_keys=True)}", flush=True)
    record(f"fig6_{tag}_phi_trace", **summary,
           events=[{k: v for k, v in e.items() if k != "piece_sizes"}
                   for e in refreshes[-8:]])


def run_wl(tag, wl, n):
    # without reuse: random-walk warm-up, then plain Algorithm 1
    t0 = time.perf_counter()
    wr = warmup(wl.cat, wl.joins, method="random_walk", rw_max_walks=2000)
    est = estimate_union(wr.oracle)
    s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=0)
    s.sample(n)
    t_plain = time.perf_counter() - t0

    # with reuse: Algorithm 2 (hist init + rw refinement + pool reuse)
    t0 = time.perf_counter()
    ou = OnlineUnionSampler(wl.cat, wl.joins, seed=0, phi=1024, rw_batch=256)
    ss = ou.sample(n)
    t_reuse = time.perf_counter() - t0

    emit(f"fig6_{tag}_no_reuse", t_plain / n * 1e6, "")
    emit(f"fig6_{tag}_reuse", t_reuse / n * 1e6,
         f"reuse_accepts={ss.stats.reuse_accepts};speedup={t_plain/max(t_reuse,1e-9):.2f}x")
    _dump_trace(tag, ou)


def main(small: bool = True, json_path: str = None) -> None:
    n = 500 if small else 5000
    scale = 0.05 if small else 0.3
    run_wl("uq1", uq1(scale=scale, overlap=0.3, n_joins=3), n)
    run_wl("uq2", uq2(scale=scale), n)
    run_wl("uq3", uq3(scale=scale, overlap=0.3), n)
    if json_path:
        from .common import write_json
        write_json(json_path, bench="reuse")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--json", default=None,
                    help="append a run (records + phi traces) to this "
                         "BENCH json file")
    a = ap.parse_args()
    main(small=a.small, json_path=a.json)
