"""Fig 5b: SetUnion sampling time vs TPC-H data scale (UQ1)."""

from __future__ import annotations

import time

from repro.core.framework import estimate_union, warmup
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1

from .common import emit


def main(small: bool = True) -> None:
    scales = [0.05, 0.1] if small else [0.1, 0.3, 0.5, 1.0]
    n = 300 if small else 3000
    for sc in scales:
        wl = uq1(scale=sc, overlap=0.3, seed=0, n_joins=3)
        for jm in ("ew", "eo"):
            wr = warmup(wl.cat, wl.joins, method="histogram")
            est = estimate_union(wr.oracle)
            s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=0,
                                join_method=jm)
            t0 = time.perf_counter()
            s.sample(n)
            dt = time.perf_counter() - t0
            emit(f"fig5b_uq1_scale{sc}_{jm}", dt / n * 1e6, f"n={n}")


if __name__ == "__main__":
    main(small=False)
