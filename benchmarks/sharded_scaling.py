"""Sharded-engine scaling: samples/sec vs mesh size on a 2-join union.

Sweeps the mesh-sharded Algorithm-1 engine
(:class:`repro.core.sharding.ShardedUnionSampler` via
``SetUnionSampler(backend="jax", mesh=...)``) over mesh sizes 1..K on a
2-join TPC-H-style union (UQ1), reporting steady-state samples/sec per mesh
size and the 1→K speedup.  Weak-scaling configuration: the per-shard round
batch is fixed, so a K-shard mesh processes ``K×`` candidates per fused
round — the regime a real multi-device deployment runs in.

Needs K visible devices; on CPU the module sets
``XLA_FLAGS=--xla_force_host_platform_device_count=<K>`` *before* importing
jax when run as a script.  From ``benchmarks.run`` (where jax is already
initialised) the sweep re-executes itself in a subprocess with the flag set.

Reading the numbers: host-platform devices *emulate* a mesh by running each
shard's program in its own thread of one CPU, so the attainable samples/sec
speedup is bounded by the physical core count, not by the mesh size — on a
>=8-core host the 1→8 sweep shows the >=3x target; on a 2-core container it
saturates near the all-cores single-device rate (use ``--require-speedup``
to gate only on real parallel hardware).

    PYTHONPATH=src python -m benchmarks.sharded_scaling --smoke
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

_DEF_DEVICES = 8


def _sweep(args) -> int:
    """Run the mesh sweep (assumes the device count is already forced)."""
    import time

    import numpy as np

    from repro.core.framework import estimate_union, warmup
    from repro.core.sharding import make_sampler_mesh
    from repro.core.union_sampler import SetUnionSampler
    from repro.data.workloads import uq1
    from repro.serve.service import SampleService

    from benchmarks.common import emit, record, write_json

    import jax
    ndev = len(jax.devices())
    wl = uq1(scale=args.scale, overlap=0.5, seed=1, n_joins=2)
    wr = warmup(wl.cat, wl.joins, method="histogram")
    est = estimate_union(wr.oracle)

    worlds = [w for w in (1, 2, 4, 8, 16) if w <= ndev]
    cores = os.cpu_count() or 1
    rates = {}
    last = None
    for world in worlds:
        mesh = make_sampler_mesh(world=world)
        s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=7,
                            backend="jax", round_batch=args.round_batch,
                            mesh=mesh)
        s.sample(args.warm)                  # compile + warm the banks
        s.sample(args.samples)               # compile the n-capacity loop
        bt = sum(getattr(s._engine, "piece_batches", None)
                 or [args.round_batch * world])
        it0, cd0 = s.stats.iterations, s.stats.candidate_draws
        t0 = time.perf_counter()
        s.sample(args.samples)
        dt = time.perf_counter() - t0
        rate = args.samples / max(dt, 1e-9)
        rates[world] = rate
        last = s
        emit(f"sharded_union_w{world}", dt / args.samples * 1e6,
             f"{rate:,.0f} samples/s ({world} shards, "
             f"per-shard round_batch={args.round_batch})")
        record(f"sharded_union_w{world}", world=world,
               round_batch=args.round_batch, n=args.samples, seconds=dt,
               samples_per_s=rate, cpu_count=cores,
               rounds=(s.stats.iterations - it0) // max(bt, 1),
               psi=(s.stats.candidate_draws - cd0) / args.samples)

    # pipelined serving path: dispatch-then-drain double buffering hides the
    # host-side batch assembly behind the next round's device compute
    if last is not None:
        world = worlds[-1]
        with SampleService(last, batch=max(args.round_batch, 4096),
                           prefetch=2) as svc:
            svc.request(args.warm)           # producer warm + queue primed
            t0 = time.perf_counter()
            svc.request(args.samples)
            dt = time.perf_counter() - t0
        rate = args.samples / max(dt, 1e-9)
        emit(f"serve_pipelined_w{world}", dt / args.samples * 1e6,
             f"{rate:,.0f} samples/s through SampleService "
             f"(async double-buffered rounds, {world} shards)")
        record(f"serve_pipelined_w{world}", world=world,
               round_batch=args.round_batch, n=args.samples, seconds=dt,
               samples_per_s=rate, cpu_count=cores, pipelined=True)
    if len(worlds) > 1:
        speedup = rates[worlds[-1]] / max(rates[1], 1e-9)
        emit("sharded_scaling", 0.0,
             f"{speedup:.2f}x samples/s from 1 -> {worlds[-1]} shards "
             f"(host has {cores} cores; emulated multi-device scaling is "
             f"bounded by min(shards, cores)/shard-efficiency)")
        record("sharded_scaling_summary", worlds=worlds, cpu_count=cores,
               speedup=speedup,
               speedup_gated=bool(args.require_speedup)
               and cores >= worlds[-1])
        if args.require_speedup:
            if cores < worlds[-1]:
                # host-platform shards emulate devices on threads of the
                # same CPUs — with fewer physical cores than shards the
                # "scaling" number measures core contention, not the engine
                print(f"SKIP: --require-speedup {args.require_speedup}x not "
                      f"gated ({cores} physical cores < {worlds[-1]} shards; "
                      "emulated mesh is core-bound)", flush=True)
            elif speedup < args.require_speedup:
                print(f"FAIL: speedup {speedup:.2f}x < required "
                      f"{args.require_speedup}x", flush=True)
                return 1
    write_json(args.json, bench="sharded_scaling", scale=args.scale)
    return 0


def _respawn(argv, devices: int) -> int:
    """Re-run this module in a subprocess with the device count forced."""
    env = dict(os.environ)
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={devices}"])
    env.setdefault("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-m", "benchmarks.sharded_scaling",
                        *argv], env=env)
    return r.returncode


def main(small: bool = True) -> None:
    """benchmarks.run entry point — jax is already live there, so re-exec."""
    argv = ["--smoke"] if small else []
    rc = _respawn(argv, _DEF_DEVICES)
    if rc:
        raise RuntimeError(f"sharded_scaling subprocess failed (rc={rc})")


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=_DEF_DEVICES)
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--samples", type=int, default=None)
    ap.add_argument("--warm", type=int, default=None)
    ap.add_argument("--round-batch", type=int, default=None)
    ap.add_argument("--require-speedup", type=float, default=0.0,
                    help="exit non-zero when 1->K speedup is below this")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write structured results (BENCH_serve.json)")
    args = ap.parse_args(argv)
    if args.scale is None:
        args.scale = 0.05 if args.smoke else 0.2
    if args.samples is None:
        args.samples = 60_000 if args.smoke else 400_000
    if args.warm is None:
        args.warm = 4096
    if args.round_batch is None:
        args.round_batch = 1024 if args.smoke else 4096
    return args


if __name__ == "__main__":
    args = _parse()
    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", "") and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count="
                                   f"{args.devices}").strip()
    from benchmarks.common import header
    header()
    sys.exit(_sweep(args))
