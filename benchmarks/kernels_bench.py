"""Kernel micro-benchmarks (interpret-mode functional timing + op census).

Wall-clock on CPU interpret mode is NOT a TPU number — rows report the
per-call operation counts that the §Roofline kernel story uses (compares the
fused hop against its unfused two-searchsorted + pick decomposition).
"""

from __future__ import annotations

import numpy as np

from repro.kernels import ops, ref

from .common import emit, timed


def main(small: bool = True) -> None:
    rng = np.random.default_rng(0)
    nk = 20_000 if small else 200_000
    nq = 2_000 if small else 20_000
    keys = np.sort(rng.integers(0, nk // 4, nk).astype(np.int64))
    qs = rng.integers(0, nk // 4, nq).astype(np.int64)
    u = rng.random(nq).astype(np.float32)

    t = timed(lambda: ops.searchsorted(keys, qs), repeats=3)
    emit("kernel_searchsorted", t * 1e6, f"nk={nk};nq={nq}")
    t = timed(lambda: ops.walk_hop(keys, qs, u), repeats=3)
    emit("kernel_walk_hop_fused", t * 1e6, "fuses refine+pick (1 pass)")
    t = timed(lambda: ops.segdegree(keys), repeats=3)
    emit("kernel_segdegree", t * 1e6, f"nk={nk}")

    B, H, KVH, D, S = (2, 8, 4, 128, 1024) if small else (4, 16, 8, 128, 4096)
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    k = rng.standard_normal((B, S, KVH, D)).astype(np.float32)
    v = rng.standard_normal((B, S, KVH, D)).astype(np.float32)
    lens = np.full(B, S)
    t = timed(lambda: ops.decode_attention(q, k, v, lens), repeats=2)
    emit("kernel_decode_attention", t * 1e6, f"B{B}H{H}S{S}")


if __name__ == "__main__":
    main(small=False)
