"""Fig 5f/5g/5h: runtime breakdown — parameter estimation vs accepted vs
rejected sample time."""

from __future__ import annotations

import time

from repro.core.framework import estimate_union, warmup
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1, uq2, uq3

from .common import emit


def run_wl(tag, wl, n, warm):
    t0 = time.perf_counter()
    wr = warmup(wl.cat, wl.joins, method=warm,
                **({"rw_max_walks": 2000} if warm == "random_walk" else {}))
    est = estimate_union(wr.oracle)
    t_warm = time.perf_counter() - t0
    s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=0)
    t0 = time.perf_counter()
    ss = s.sample(n)
    t_sample = time.perf_counter() - t0
    draws = max(ss.stats.candidate_draws, 1)
    rej = ss.stats.cover_rejects
    acc_frac = n / draws
    t_rej = t_sample * (rej / draws)
    t_acc = t_sample - t_rej
    emit(f"fig5fgh_{tag}_{warm}_warmup", t_warm * 1e6, f"n={n}")
    emit(f"fig5fgh_{tag}_{warm}_accepted", t_acc / n * 1e6,
         f"accept_frac={acc_frac:.3f}")
    emit(f"fig5fgh_{tag}_{warm}_rejected", t_rej / max(rej, 1) * 1e6,
         f"rejects={rej}")


def main(small: bool = True) -> None:
    n = 500 if small else 5000
    scale = 0.05 if small else 0.3
    for tag, wl in (("uq1", uq1(scale=scale, overlap=0.3, n_joins=3)),
                    ("uq2", uq2(scale=scale)),
                    ("uq3", uq3(scale=scale, overlap=0.3))):
        for warm in ("histogram", "random_walk"):
            run_wl(tag, wl, n, warm)


if __name__ == "__main__":
    main(small=False)
