"""Fig 5c/5d/5e: SetUnion sampling time vs sample count N.

Warm-up (HISTOGRAM vs RANDOM-WALK parameters) × join-sampler weights (EW vs
EO), per workload.
"""

from __future__ import annotations

import time

from repro.core.framework import estimate_union, warmup
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1, uq2, uq3

from .common import emit


def run_wl(tag, wl, ns, warm="exact", join_method="ew"):
    wr = warmup(wl.cat, wl.joins, method=warm,
                **({"rw_max_walks": 2000} if warm == "random_walk" else {}))
    est = estimate_union(wr.oracle)
    for n in ns:
        s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=0,
                            join_method=join_method)
        t0 = time.perf_counter()
        ss = s.sample(n)
        dt = time.perf_counter() - t0
        emit(f"fig5cde_{tag}_{warm}_{join_method}_N{n}", dt / n * 1e6,
             f"reject_rate={ss.stats.cover_rejects/max(ss.stats.iterations,1):.3f}")


def main(small: bool = True) -> None:
    ns = [200, 1000] if small else [1000, 5000, 20000]
    scale = 0.05 if small else 0.3
    wl1 = uq1(scale=scale, overlap=0.3, seed=0, n_joins=3)
    for wm in ("histogram", "random_walk"):
        for jm in ("ew", "eo"):
            run_wl("uq1", wl1, ns, warm=wm, join_method=jm)
    wl2 = uq2(scale=scale, seed=0)
    run_wl("uq2", wl2, ns, warm="histogram", join_method="ew")
    wl3 = uq3(scale=scale, overlap=0.3, seed=0)
    run_wl("uq3", wl3, ns, warm="histogram", join_method="ew")


if __name__ == "__main__":
    main(small=False)
