"""Benchmark orchestrator — one module per paper figure (DESIGN.md §9).

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` uses the paper-scale
settings (minutes); default is the quick functional pass.  ``--json PATH``
additionally persists every structured :func:`benchmarks.common.record` row
(plus git sha / device count meta) as one JSON trajectory file.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (bias_ablation, breakdown, data_scale, device_sampler,
               estimation_device, estimation_error, estimation_runtime,
               kernels_bench, reuse, roofline, sampling_scaling,
               sharded_scaling, union_engine)
from .common import emit, header, write_json

MODULES = [
    ("estimation_error", estimation_error),     # Fig 4a/4b + 5a
    ("estimation_runtime", estimation_runtime), # Fig 4c/4d
    ("estimation_device", estimation_device),   # device walk+probe batches
    ("sampling_scaling", sampling_scaling),     # Fig 5c/5d/5e
    ("breakdown", breakdown),                   # Fig 5f/5g/5h
    ("data_scale", data_scale),                 # Fig 5b
    ("reuse", reuse),                           # Fig 6a/6b
    ("bias_ablation", bias_ablation),           # DESIGN §7.9 ablation
    ("device_sampler", device_sampler),         # host vs jitted sampler
    ("union_engine", union_engine),             # fused union rounds (backends)
    ("sharded_scaling", sharded_scaling),       # mesh scaling (subprocess)
    ("kernels_bench", kernels_bench),           # kernel micro-bench
    ("roofline", roofline),                     # §Roofline table
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write structured records as a JSON trajectory")
    args = ap.parse_args()
    header()
    t0 = time.time()
    failures = 0
    for name, mod in MODULES:
        if args.only and name != args.only:
            continue
        ts = time.time()
        try:
            mod.main(small=not args.full)
            emit(f"_section_{name}", (time.time() - ts) * 1e6, "ok")
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            traceback.print_exc()
            emit(f"_section_{name}", (time.time() - ts) * 1e6,
                 f"FAILED:{type(e).__name__}")
    emit("_total", (time.time() - t0) * 1e6,
         f"failures={failures}")
    write_json(args.json, full=args.full)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
