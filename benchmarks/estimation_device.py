"""Device estimation: jitted walk+probe+HT batches vs the host loop.

Times one ONLINE-UNION refinement observation (a wander-join walk batch on
the pivot join, membership probes against the other join of Δ, and both HT
accumulator updates) on the 2-join TPC-H union workload (UQ1, n_joins=2):

* ``host`` — :class:`~repro.core.estimators.numpy_estimator.NumpyEstimator`
  at the ONLINE-UNION production default batch (``rw_batch=256``) and at the
  device's batch, per-walk cost in µs,
* ``device`` — :class:`~repro.core.estimators.jax_estimator.JaxEstimator`'s
  fused jitted program at its design-point batch.

The headline row compares each engine at its production configuration: the
host loop cannot profitably grow its batch (the per-element Welford update
and the per-round Python dispatch scale linearly), while the device engine
exists precisely to fuse large batches into one program.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.estimators import NumpyEstimator
from repro.core.estimators.jax_estimator import JaxEstimator, DeviceRunning

from .common import emit


def _host_us_per_walk(wl, batch: int, rounds: int) -> float:
    h = NumpyEstimator(wl.cat, wl.joins, seed=0, batch=batch)
    h.observe(wl.joins, rounds=1)                      # warm caches
    t0 = time.perf_counter()
    h.observe(wl.joins, rounds=rounds)
    return (time.perf_counter() - t0) / (rounds * batch) * 1e6


def _device_us_per_walk(wl, batch: int, rounds: int) -> float:
    import jax
    d = JaxEstimator(wl.cat, wl.joins, seed=0, batch=batch)
    pivot = d._pivot(wl.joins)
    others = tuple(sorted(j.name for j in wl.joins if j.name != pivot.name))
    fn = d._observe_fn(pivot.name, others)
    ss, st = DeviceRunning(), DeviceRunning()
    key = jax.random.PRNGKey(0)
    jax.block_until_ready(fn(key, ss.state, st.state))  # compile
    ts = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(sub, ss.state, st.state))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) / batch * 1e6


def main(small: bool = True) -> None:
    from repro.data.workloads import uq1
    scale = 0.02 if small else 0.05
    host_batch = 256                     # OnlineUnionSampler rw_batch default
    dev_batch = 2048 if small else 16384
    rounds = 4 if small else 10
    wl = uq1(scale=scale, overlap=0.3, seed=0, n_joins=2)

    t_host = _host_us_per_walk(wl, host_batch, rounds)
    t_host_big = _host_us_per_walk(wl, dev_batch, max(rounds // 2, 2))
    t_dev = _device_us_per_walk(wl, dev_batch, rounds)

    emit("est_dev_host_loop", t_host,
         f"us_per_walk@batch={host_batch}")
    emit("est_dev_host_bigbatch", t_host_big,
         f"us_per_walk@batch={dev_batch}")
    emit("est_dev_device_fused", t_dev,
         f"us_per_walk@batch={dev_batch}")
    emit("est_dev_speedup", t_host / max(t_dev, 1e-9),
         f"device_vs_host_loop={t_host / max(t_dev, 1e-9):.1f}x "
         f"equal_batch={t_host_big / max(t_dev, 1e-9):.1f}x")


if __name__ == "__main__":
    main(small=False)
