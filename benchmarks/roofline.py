"""§Roofline reader: tabulate the dry-run artifacts (not a paper figure).

Reads artifacts/<mesh>/<arch>__<shape>.json produced by repro.launch.dryrun
and emits one row per cell with the three roofline terms and the bottleneck.
"""

from __future__ import annotations

import glob
import json
import os

from .common import emit


def main(small: bool = True, artifacts: str = "artifacts") -> None:
    files = sorted(glob.glob(os.path.join(artifacts, "*", "*.json")))
    if not files:
        emit("roofline_no_artifacts", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun")
        return
    for f in files:
        d = json.load(open(f))
        mesh = os.path.basename(os.path.dirname(f))
        tag = f"roofline_{mesh}_{d['arch']}_{d['shape']}"
        if d.get("skipped"):
            emit(tag, 0.0, "skipped")
            continue
        if "error" in d:
            emit(tag, 0.0, f"ERROR={d['error'][:60]}")
            continue
        r = d["roofline"]
        dom_t = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / max(dom_t, 1e-12)
        emit(tag, d.get("compile_s", 0.0) * 1e6,
             f"dom={r['dominant']};compute_s={r['compute_s']:.3f};"
             f"memory_s={r['memory_s']:.3f};collective_s={r['collective_s']:.3f};"
             f"roofline_frac={frac:.3f};"
             f"mem_GiB={d['memory']['per_device_total']/2**30:.2f};"
             f"useful={r['useful_flops_ratio']:.3f}")


if __name__ == "__main__":
    main(small=False)
