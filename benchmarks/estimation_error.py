"""Fig 4a/4b + Fig 5a: error of the |J_i|/|U| ratio estimation.

HISTOGRAM-BASED (+EO join sizes) and RANDOM-WALK vs the exact FULLJOIN ground
truth, on UQ1 and UQ3, across overlap scales.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.framework import estimate_union, warmup
from repro.data.workloads import uq1, uq3

from .common import emit, timed


def ratio_errors(wl, method, **kw):
    ex = warmup(wl.cat, wl.joins, method="exact")
    est_ex = estimate_union(ex.oracle)
    t0 = time.perf_counter()
    wr = warmup(wl.cat, wl.joins, method=method, **kw)
    est = estimate_union(wr.oracle)
    dt = time.perf_counter() - t0
    errs = []
    for j in wl.joins:
        r_true = ex.oracle.size(j.name) / max(est_ex.union_size_cover, 1e-9)
        r_est = wr.oracle.size(j.name) / max(est.union_size_cover, 1e-9)
        if r_true > 0:
            errs.append(abs(r_est - r_true) / r_true)
    return float(np.mean(errs)) if errs else 0.0, dt


def main(small: bool = True) -> None:
    scale = 0.05 if small else 0.3
    overlaps = [0.2, 0.5] if small else [0.1, 0.2, 0.4, 0.6, 0.8]
    for ov in overlaps:
        wl = uq1(scale=scale, overlap=ov, seed=0, n_joins=3)
        err_h, t_h = ratio_errors(wl, "histogram")
        emit(f"fig4a_uq1_hist_ov{ov}", t_h * 1e6, f"ratio_err={err_h:.3f}")
        err_r, t_r = ratio_errors(wl, "random_walk",
                                  rw_max_walks=4000 if small else 20000)
        emit(f"fig5a_uq1_rw_ov{ov}", t_r * 1e6, f"ratio_err={err_r:.3f}")
    for ov in overlaps:
        wl = uq3(scale=scale, overlap=ov, seed=0)
        err_h, t_h = ratio_errors(wl, "histogram")
        emit(f"fig4b_uq3_hist_ov{ov}", t_h * 1e6, f"ratio_err={err_h:.3f}")


if __name__ == "__main__":
    main(small=False)
