"""Shared benchmark utilities: timing + CSV row emission.

Every benchmark module reproduces one paper figure/table (DESIGN.md §9) and
emits ``name,us_per_call,derived`` CSV rows via :func:`emit`.
"""

from __future__ import annotations

import sys
import time
from typing import Callable, Optional

ROWS = []


def timed(fn: Callable, repeats: int = 1) -> float:
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)
