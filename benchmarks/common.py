"""Shared benchmark utilities: timing, CSV row emission, JSON trajectories.

Every benchmark module reproduces one paper figure/table (DESIGN.md §9) and
emits ``name,us_per_call,derived`` CSV rows via :func:`emit`.  Modules that
feed the perf trajectory additionally call :func:`record` with structured
fields (samples/s, rounds, psi, ...) and the driver persists them with
:func:`write_json` — the ``BENCH_*.json`` files the ROADMAP tracks.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Callable, Dict, List, Optional

ROWS = []
RECORDS: List[Dict] = []


def timed(fn: Callable, repeats: int = 1) -> float:
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)


# ------------------------------------------------------------- JSON writer
def record(name: str, **fields) -> None:
    """Append one structured benchmark record for the JSON trajectory."""
    RECORDS.append({"name": name, **fields})


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def bench_meta() -> Dict:
    """Environment stamp shared by every BENCH_*.json file."""
    meta: Dict = {
        "git_sha": git_sha(),
        "python": sys.version.split()[0],
        "cpu_count": os.cpu_count(),
    }
    try:
        import jax
        meta["jax_version"] = jax.__version__
        meta["device_count"] = jax.device_count()
        meta["platform"] = jax.devices()[0].platform
    except Exception:
        meta["jax_version"] = None
        meta["device_count"] = 0
    return meta


HISTORY_CAP = 100       # appended runs kept per BENCH_*.json file


def _load_history(path: str) -> List[Dict]:
    """Prior runs from an existing BENCH file (migrating legacy layouts)."""
    try:
        with open(path) as f:
            prev = json.load(f)
    except Exception:
        return []
    history = list(prev.get("history", []))
    if not history and prev.get("records"):
        # legacy clobber-style file: keep its one run as the first entry
        history = [{"ts": None,
                    "git_sha": prev.get("meta", {}).get("git_sha", "unknown"),
                    "meta": prev.get("meta", {}),
                    "records": prev.get("records", [])}]
    return history


def write_json(path: Optional[str], records: Optional[List[Dict]] = None,
               **extra_meta) -> None:
    """Persist ``records`` (default: the global RECORDS) plus meta to PATH.

    Appends rather than clobbers: each call adds one timestamped,
    git-sha-stamped run to the file's ``history`` list (capped at
    ``HISTORY_CAP``), while the latest run stays under ``records``/``meta``
    for consumers that only want the freshest numbers.
    """
    if not path:
        return
    meta = {**bench_meta(), **extra_meta}
    recs = list(RECORDS if records is None else records)
    run = {"ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
           "git_sha": meta.get("git_sha", "unknown"),
           "meta": meta, "records": recs}
    history = _load_history(path) if os.path.exists(path) else []
    history.append(run)
    payload = {"meta": meta, "records": recs,
               "history": history[-HISTORY_CAP:]}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(recs)} records, "
          f"{len(payload['history'])} runs in history)", flush=True)
